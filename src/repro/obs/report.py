"""Incident report renderer: flight bundles / JSONL traces -> human text.

    python -m repro.obs.report experiments/flight/flight-*.json
    python -m repro.obs.report experiments/bench/trace_obs.jsonl

Takes either a flight-recorder bundle (``flight.py``) or a raw JSONL
trace (``export_jsonl``) and prints an incident summary: what fired (the
alert's tenant / program / window), the partition-health gauges at
capture time, per-name event counts, a latency digest per span name, any
span whose parent was overwritten out of the ring, and the tail of the
event timeline.  Pure stdlib + stdout: the point is to be runnable from
a CI artifact download with nothing installed.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from .flight import BUNDLE_MARKER
from .histogram import LogHistogram


def load(path: str) -> dict:
    """Load a bundle (single JSON object) or a JSONL trace (one event per
    line), normalised to the bundle schema."""
    p = pathlib.Path(path)
    text = p.read_text()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and BUNDLE_MARKER in doc:
        return doc
    if isinstance(doc, dict) and "traceEvents" in doc:   # chrome trace
        return {"reason": f"trace {p.name}", "events": doc["traceEvents"]}
    events = []
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError as e:
            raise SystemExit(
                f"ERROR: {path}:{i + 1}: neither a flight bundle nor "
                f"parseable JSONL ({e})")
    return {"reason": f"trace {p.name}", "events": events}


def _fmt_val(v, width: int = 60) -> str:
    s = json.dumps(v, default=str) if isinstance(v, (dict, list)) else str(v)
    return s if len(s) <= width else s[:width - 3] + "..."


def _alert_lines(alert: dict) -> list[str]:
    kind = alert.get("kind", "unknown")
    out = [f"  kind       {kind}"]
    if kind == "burn_rate":
        win = alert.get("window", {})
        out += [
            f"  policy     {alert.get('policy')}",
            f"  tenant     {alert.get('tenant')}",
            f"  program    {alert.get('program')}",
            f"  objective  latency <= {alert.get('objective_s')}s at "
            f"{alert.get('availability_target'):.3%} availability",
            f"  burn rate  fast {alert.get('burn_fast')}x / slow "
            f"{alert.get('burn_slow')}x (threshold "
            f"{alert.get('threshold')}x)",
            f"  window     fast {win.get('fast_s')}s: "
            f"{_fmt_val(win.get('fast'))}",
            f"             slow {win.get('slow_s')}s: "
            f"{_fmt_val(win.get('slow'))}",
        ]
    elif kind == "gauge_drift":
        out += [f"  gauge      {alert.get('gauge')} = {alert.get('value')}"
                f" (baseline {alert.get('baseline')})"]
        out += [f"  breach     {r}" for r in alert.get("reasons", [])]
    elif kind == "retrace_rate":
        win = alert.get("window", {})
        out += [f"  rate       {alert.get('rate_per_s')}/s over "
                f"{win.get('window_s')}s (max {alert.get('max_per_s')}/s, "
                f"{win.get('retraces')} retraces)"]
    else:
        out += [f"  context    {_fmt_val(alert)}"]
    return out


def render(bundle: dict, tail: int = 15) -> str:
    """One incident summary string for a bundle/trace document."""
    events = bundle.get("events", [])
    lines = ["=" * 72,
             f"INCIDENT  {bundle.get('reason', '?')}"]
    if "created_utc" in bundle:
        lines.append(f"captured  {bundle['created_utc']} "
                     f"(bundle seq {bundle.get('seq')})")
    stats = bundle.get("stats")
    if stats:
        lines.append(
            f"recorder  {stats.get('since_reset', 0)} events in ring, "
            f"{stats.get('dropped', 0)} dropped since reset, "
            f"{stats.get('overwritten', 0)} overwritten lifetime, "
            f"{stats.get('open_spans', 0)} open spans")
    lines.append("=" * 72)

    context = bundle.get("context")
    alerts = [e["args"] for e in events if e.get("name") == "obs.alert"]
    if isinstance(context, dict) and context.get("kind"):
        alerts = [context] + [a for a in alerts if a != context]
    if alerts:
        lines.append(f"\nALERTS ({len(alerts)})")
        for a in alerts:
            lines += _alert_lines(a)
            lines.append("")
    snap = bundle.get("snapshot", {})
    active = []
    for v in snap.values():
        if isinstance(v, dict):
            active += v.get("active_alerts", [])
    if active and not alerts:
        lines.append(f"\nACTIVE ALERTS AT CAPTURE ({len(active)})")
        for a in active:
            lines += _alert_lines(a)
            lines.append("")

    gauges = snap.get("gauges", {})
    if gauges:
        lines.append("HEALTH GAUGES")
        for k in sorted(gauges):
            lines.append(f"  {k:<40} {gauges[k]}")
    counters = snap.get("counters", {})
    if counters:
        lines.append("COUNTERS")
        for k in sorted(counters):
            lines.append(f"  {k:<40} {counters[k]}")

    by_name: dict[str, int] = {}
    spans: dict[str, LogHistogram] = {}
    dangling = 0
    span_ids = {e["args"]["span_id"] for e in events
                if "span_id" in e.get("args", {})}
    for e in events:
        by_name[e["name"]] = by_name.get(e["name"], 0) + 1
        args = e.get("args", {})
        pid = args.get("parent_id", args.get("dangling_parent_id"))
        if pid is not None and pid not in span_ids:
            dangling += 1
        if e.get("ph") == "X":
            spans.setdefault(e["name"], LogHistogram()).record(
                float(e.get("dur", 0.0)) * 1e-6)
    if by_name:
        lines.append(f"\nEVENTS ({len(events)} in ring)")
        for k in sorted(by_name, key=by_name.get, reverse=True):
            lines.append(f"  {k:<40} {by_name[k]}")
    if dangling:
        lines.append(f"  [!] {dangling} span(s) with a parent overwritten "
                     "out of the ring (re-parented to root on export)")
    if spans:
        lines.append("\nSPAN LATENCY (seconds)")
        lines.append(f"  {'span':<24} {'n':>6} {'p50':>10} {'p99':>10} "
                     f"{'max':>10}")
        for k in sorted(spans):
            h = spans[k]
            lines.append(f"  {k:<24} {h.n:>6} {h.percentile(50):>10.6f} "
                         f"{h.percentile(99):>10.6f} {h.vmax:>10.6f}")

    if events:
        lines.append(f"\nTIMELINE TAIL (last {min(tail, len(events))} "
                     "events, ts in s since recorder start)")
        for e in events[-tail:]:
            ts = float(e.get("ts", 0.0)) * 1e-6
            args = {k: v for k, v in e.get("args", {}).items()
                    if k not in ("span_id", "parent_id")}
            lines.append(f"  {ts:>10.4f}  {e['name']:<24} "
                         f"{_fmt_val(args, 70)}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="render a flight-recorder bundle or JSONL trace into "
                    "a human-readable incident summary")
    ap.add_argument("path", nargs="+",
                    help="flight-*.json bundle(s) or a JSONL trace")
    ap.add_argument("--tail", type=int, default=15,
                    help="timeline tail length (default 15)")
    args = ap.parse_args(argv)
    for p in args.path:
        print(render(load(p), tail=args.tail))
    return 0


if __name__ == "__main__":
    sys.exit(main())

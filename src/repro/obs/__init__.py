"""repro.obs — end-to-end tracing + partition-health telemetry.

One low-overhead observability layer threaded through partition -> engine
-> stream -> serve: a process-global ``Recorder`` (fixed-size ring buffer
of structured events and spans, no-op when disabled) that every subsystem
records into, partition-health gauges (replication factor, balance,
slack) stamped on every installed plan mutation, jit retraces surfaced as
attributable events, and exporters to JSONL and Chrome trace-event format
so a served request can be followed from admission to host
materialisation in Perfetto.  On top of the passive layer sits the active
half: mergeable log-bucketed histograms (``LogHistogram`` /
``WindowedHistogram``), a multi-window burn-rate SLO ``Monitor`` that
emits first-class ``obs.alert`` events, and a ``FlightRecorder`` that
dumps bounded postmortem bundles the instant an alert fires (render with
``python -m repro.obs.report``), and the cost-attribution layer:
static per-executable ``CostModel``s from post-optimization HLO
(``obs.profile``) joined with measured execute spans into a mergeable
per-tenant ``CostLedger`` (``obs.ledger``, render with ``python -m
repro.obs.usage``) that prices cost-aware admission in gserve.  See
src/repro/obs/README.md for the event schema, span/alert taxonomy,
ledger schema and overhead contract.

Typical use::

    from repro import obs
    obs.enable()
    ... serve queries, apply stream updates ...
    print(obs.snapshot())                  # whole-hierarchy live stats
    obs.export_chrome_trace("trace.json")  # open in ui.perfetto.dev
"""
from .export import export_chrome_trace, export_jsonl
from .flight import FlightRecorder
from .health import plan_health
from .histogram import LogHistogram, WindowedHistogram
from .ledger import CostLedger, CostSample, get_ledger
from .monitor import GaugeWatch, Monitor, SLOPolicy
from .profile import CostModel, cost_model
from .recorder import Recorder, get

__all__ = [
    "CostLedger", "CostModel", "CostSample", "FlightRecorder",
    "GaugeWatch", "LogHistogram", "Monitor", "Recorder", "SLOPolicy",
    "WindowedHistogram", "cost_model", "disable", "enable", "event",
    "export_chrome_trace", "export_jsonl", "get", "get_ledger",
    "plan_health", "reset", "snapshot",
]


def enable(capacity: int | None = None) -> None:
    get().enable(capacity)


def disable() -> None:
    get().disable()


def reset() -> None:
    get().reset()


def event(name: str, **args) -> None:
    get().event(name, **args)


def snapshot() -> dict:
    return get().snapshot()

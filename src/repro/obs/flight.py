"""Flight recorder: automatic postmortem bundles at the moment of breach.

A trace exported *after* an incident has usually lost the interesting
part — the ring buffer kept rolling.  ``FlightRecorder`` captures the
whole observable state the instant something goes wrong (an ``obs.alert``
firing, a benchmark figure crashing, a tier-1 test failing) into one
self-contained JSON *bundle*:

  * the triggering ``reason`` + alert/context payload,
  * ``Recorder.stats()`` and the full ``obs.snapshot()`` (counters,
    gauges, every provider — the cache hierarchy, serve metrics, monitor
    state; a raising provider degrades to ``{"error": ...}`` instead of
    aborting the dump),
  * the ring buffer contents (every event still in the ring, oldest
    first).

Bundles are **bounded**: at most ``max_bundles`` newest files are kept
per directory (oldest deleted on each dump), so an alert storm cannot
fill a disk.  ``arm(monitor)`` subscribes the dump to a ``Monitor``'s
``on_alert`` hook; CI arms it via the ``REPRO_FLIGHT_DIR`` environment
variable (``benchmarks/run.py`` for bench figures, ``tests/conftest.py``
for tier-1 failures) and uploads the directory as a workflow artifact
when the job fails.

Render a bundle with ``python -m repro.obs.report <bundle.json>``.

Timestamps: bundle *filenames* carry wall-clock UTC (an incident is
looked up by when it happened), via ``datetime`` — the monotonic-only
discipline applies to measured intervals, not to naming.
"""
from __future__ import annotations

import datetime
import itertools
import json
import os
import pathlib
import re
from typing import Callable

from .recorder import Recorder, get

BUNDLE_MARKER = "flight_bundle"        # schema tag + version
BUNDLE_VERSION = 1
_SEQ = itertools.count()


def _slug(text: str, max_len: int = 48) -> str:
    """Filesystem-safe reason slug."""
    s = re.sub(r"[^A-Za-z0-9._-]+", "-", str(text)).strip("-.")
    return s[:max_len] or "dump"


class FlightRecorder:
    """Dumps bounded, timestamped postmortem bundles into one directory."""

    def __init__(self, out_dir: str, *, max_bundles: int = 8,
                 recorder: Recorder | None = None):
        if max_bundles < 1:
            raise ValueError("max_bundles must be >= 1")
        self.out_dir = pathlib.Path(out_dir)
        self.max_bundles = int(max_bundles)
        self._recorder = recorder
        self.n_dumped = 0

    @property
    def recorder(self) -> Recorder:
        return self._recorder if self._recorder is not None else get()

    # -- capture -------------------------------------------------------------
    def dump(self, reason: str, context: dict | None = None) -> pathlib.Path:
        """Capture one bundle now; returns its path.  Never raises on a
        degraded recorder — the postmortem path must work when things are
        already broken."""
        rec = self.recorder
        created = datetime.datetime.now(datetime.timezone.utc)
        seq = next(_SEQ)
        bundle = {
            BUNDLE_MARKER: BUNDLE_VERSION,
            "reason": str(reason),
            "created_utc": created.isoformat(timespec="seconds"),
            "seq": seq,
            "context": context,
            "stats": rec.stats(),
            "snapshot": rec.snapshot(),
            "events": rec.events(),
        }
        self.out_dir.mkdir(parents=True, exist_ok=True)
        name = (f"flight-{created.strftime('%Y%m%dT%H%M%S')}"
                f"-{seq:04d}-{_slug(reason)}.json")
        path = self.out_dir / name
        with open(path, "w") as f:
            json.dump(bundle, f, indent=1, sort_keys=True, default=str)
        self.n_dumped += 1
        rec.event("obs.flight_dump", reason=str(reason),
                  bundle=name, seq=seq)
        self._enforce_retention()
        return path

    def _enforce_retention(self) -> None:
        """Keep only the ``max_bundles`` newest bundles (name-sorted: the
        timestamp+seq prefix makes lexical order chronological)."""
        bundles = sorted(self.out_dir.glob("flight-*.json"))
        for old in bundles[:max(0, len(bundles) - self.max_bundles)]:
            try:
                old.unlink()
            except OSError:
                pass

    def bundles(self) -> list[pathlib.Path]:
        """Retained bundles, oldest first."""
        if not self.out_dir.exists():
            return []
        return sorted(self.out_dir.glob("flight-*.json"))

    # -- arming --------------------------------------------------------------
    def arm(self, monitor) -> Callable[[], None]:
        """Dump a bundle whenever ``monitor`` fires an alert (the hook is
        edge-triggered: one bundle per fire transition, retention-bounded).
        Returns a disarm callable."""
        def _on_alert(alert: dict) -> None:
            self.dump(f"alert.{alert.get('kind', 'unknown')}",
                      context=alert)
        monitor.on_alert.append(_on_alert)

        def disarm() -> None:
            if _on_alert in monitor.on_alert:
                monitor.on_alert.remove(_on_alert)
        return disarm


def from_env(env: str = "REPRO_FLIGHT_DIR",
             max_bundles: int = 8) -> FlightRecorder | None:
    """CI auto-arming hook: a FlightRecorder over ``$REPRO_FLIGHT_DIR``
    when that variable is set, else None."""
    out = os.environ.get(env)
    return FlightRecorder(out, max_bundles=max_bundles) if out else None

"""Usage-report renderer: a cost-ledger snapshot -> per-tenant tables.

    python -m repro.obs.usage experiments/bench/usage_ledger.json
    python -m repro.obs.usage experiments/flight/flight-*.json --top 5

Takes a ``CostLedger.dump()`` snapshot, a full ``obs.snapshot()`` record
containing one, or a flight-recorder bundle (the registered ledger
provider rides inside every bundle) and prints the usage breakdown: a
per-tenant table (requests, dispatches, device seconds, windowed
device-time share, modeled flops/bytes, achieved-vs-roofline
utilization) plus the top-k most expensive series by device time.  Like
``repro.obs.report`` it is pure stdlib + stdout — runnable from a CI
artifact download with nothing installed.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from .flight import BUNDLE_MARKER
from .ledger import SNAPSHOT_KIND


def _find_ledger(doc) -> dict | None:
    """The first cost-ledger snapshot nested anywhere in ``doc``."""
    if isinstance(doc, dict):
        if doc.get("kind") == SNAPSHOT_KIND:
            return doc
        for v in doc.values():
            got = _find_ledger(v)
            if got is not None:
                return got
    return None


def load(path: str) -> dict:
    """Load a ledger snapshot from a dump, an obs snapshot, or a flight
    bundle (which embeds the full snapshot under ``"snapshot"``)."""
    p = pathlib.Path(path)
    try:
        doc = json.loads(p.read_text())
    except json.JSONDecodeError as e:
        raise SystemExit(f"ERROR: {path}: not JSON ({e})")
    if isinstance(doc, dict) and BUNDLE_MARKER in doc:
        doc = doc.get("snapshot", {})
    ledger = _find_ledger(doc)
    if ledger is None:
        raise SystemExit(
            f"ERROR: {path}: no cost-ledger snapshot found (expected a "
            f'dict with kind == "{SNAPSHOT_KIND}" at any nesting level)')
    return ledger


def _eng(v: float) -> str:
    """Engineering-compact: 1.23e9 -> '1.23G'."""
    for thresh, suffix in ((1e12, "T"), (1e9, "G"), (1e6, "M"),
                           (1e3, "k")):
        if abs(v) >= thresh:
            return f"{v / thresh:.2f}{suffix}"
    return f"{v:.2f}"


def render(ledger: dict, top: int = 10) -> str:
    totals = ledger.get("totals", {})
    tenants = ledger.get("tenants", {})
    series = ledger.get("series", [])
    lines = ["=" * 78,
             f"USAGE LEDGER  ({totals.get('series', 0)} series, "
             f"window {ledger.get('window_s')}s)",
             f"totals    {totals.get('requests', 0)} requests "
             f"({totals.get('dispatched', 0)} dispatched / "
             f"{totals.get('cached', 0)} cached), "
             f"{totals.get('device_s', 0.0):.4f} device-s, "
             f"{_eng(totals.get('flops', 0.0))}F, "
             f"{_eng(totals.get('hbm_bytes', 0.0))}B hbm, "
             f"{_eng(totals.get('coll_bytes', 0.0))}B coll",
             "=" * 78]

    if tenants:
        lines.append(
            f"\n{'tenant':<16} {'reqs':>6} {'disp':>6} {'cached':>6} "
            f"{'device_s':>10} {'share':>7} {'flops':>9} {'hbm':>9} "
            f"{'util':>6}")
        for t in sorted(tenants,
                        key=lambda t: -tenants[t].get("device_s", 0.0)):
            a = tenants[t]
            lines.append(
                f"{t:<16} {a.get('requests', 0):>6} "
                f"{a.get('dispatched', 0):>6} {a.get('cached', 0):>6} "
                f"{a.get('device_s', 0.0):>10.4f} "
                f"{a.get('window_share', 0.0):>6.1%} "
                f"{_eng(a.get('flops', 0.0)):>9} "
                f"{_eng(a.get('hbm_bytes', 0.0)):>9} "
                f"{a.get('utilization', 0.0):>6.1%}")

    ranked = sorted(series, key=lambda s: -s.get("device_s", 0.0))[:top]
    if ranked:
        lines.append(f"\nTOP {len(ranked)} SERIES BY DEVICE TIME")
        lines.append(
            f"{'tenant':<14} {'program':<12} {'graph':<14} {'ep':>3} "
            f"{'reqs':>5} {'device_s':>10} {'p99_s':>10} {'util':>6}")
        for s in ranked:
            hist = s.get("device_hist", {})
            lines.append(
                f"{s.get('tenant', '?'):<14} {s.get('program', '?'):<12} "
                f"{str(s.get('graph', '?'))[:12]:<14} "
                f"{s.get('epoch', 0):>3} {s.get('requests', 0):>5} "
                f"{s.get('device_s', 0.0):>10.4f} "
                f"{hist.get('p99', 0.0):>10.6f} "
                f"{s.get('utilization', 0.0):>6.1%}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.usage",
        description="render a cost-ledger snapshot (ledger dump, obs "
                    "snapshot, or flight bundle) as per-tenant usage "
                    "tables")
    ap.add_argument("path", nargs="+",
                    help="usage_*.json dump(s), obs snapshot, or "
                         "flight-*.json bundle(s)")
    ap.add_argument("--top", type=int, default=10,
                    help="series to list in the expensive-series table "
                         "(default 10)")
    args = ap.parse_args(argv)
    for p in args.path:
        print(render(load(p), top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""SLO burn-rate monitoring + health-drift watchers: the flight *control*.

PR 6's recorder produced flight data; this module watches it.  A
``Monitor`` holds three kinds of declarative watch:

  * **SLO policies** (``SLOPolicy``): per tenant x program (``"*"``
    wildcards), a latency objective + availability target evaluated as
    *multi-window burn rates* over windowed histograms.  A request is
    *bad* when it failed (rejected/errored) or ran slower than the
    objective; the burn rate is ``bad_fraction / (1 - availability
    target)`` — how many times faster than sustainable the error budget
    is burning.  An alert fires only when BOTH the fast and the slow
    window burn above threshold (the standard multi-window guard: the
    slow window proves the breach is real, the fast window proves it is
    *still happening*), and clears when the fast window recovers.
  * **gauge watchers** (``GaugeWatch``): absolute ceiling/floor or
    relative-drift bounds on any recorder gauge — replication factor,
    balance NSTDEV, remaining slack from ``obs/health.py`` (the axes the
    paper judges a partitioning on, arXiv 1403.6270 §V-A).
  * **retrace-rate watcher**: the ``engine.retraces`` counter turned into
    a rate; a retrace storm (a shape-stability bug) pages long before it
    shows up in tail latency.

Breaches emit first-class ``obs.alert`` events (clears emit
``obs.alert_clear``) with the offending window attached, flip the alert's
entry in ``active_alerts()``, and invoke ``on_alert`` callbacks — the
flight recorder arms itself through that hook to capture a postmortem
bundle at the moment of breach.

The monitor also aggregates **stream telemetry** (``observe_update_batch``:
update rate, slack burn) that the adaptive ``CompactionPolicy`` in
``repro.stream`` consumes to schedule proactive compaction and size slack
— closing the loop from observation back into control (the same
adaptivity-under-memory-pressure argument HEP makes for the partitioning
itself, arXiv 2103.12594).

Clock discipline: all timing is monotonic.  ``clock`` is injectable (tests
drive a fake clock); nothing here reads the wall clock.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import itertools
import time
from collections import deque
from typing import Callable

from .histogram import WindowedHistogram
from .recorder import get as _get_recorder

_MONITOR_IDS = itertools.count()     # obs provider names: monitor0, ...


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """One declarative service-level objective for tenant x program.

    ``tenant`` / ``program`` are ``fnmatch`` patterns (``"*"`` matches
    all); a wildcard policy is evaluated per concrete observed series, so
    the alert always names the actual offender.
    """
    name: str
    tenant: str = "*"
    program: str = "*"
    latency_objective_s: float = 0.1      # slower than this is "bad"
    availability_target: float = 0.99     # good-request fraction objective
    fast_window_s: float = 5.0
    slow_window_s: float = 60.0
    burn_threshold: float = 2.0           # x sustainable budget burn
    min_samples: int = 5                  # per window, below which: no verdict

    def __post_init__(self):
        if not (0.0 < self.availability_target < 1.0):
            raise ValueError(
                f"SLO {self.name!r}: availability_target must be in (0, 1)")
        if self.latency_objective_s <= 0:
            raise ValueError(
                f"SLO {self.name!r}: latency_objective_s must be > 0")
        if not (0 < self.fast_window_s <= self.slow_window_s):
            raise ValueError(
                f"SLO {self.name!r}: need 0 < fast_window_s <= slow_window_s")
        if self.burn_threshold <= 0 or self.min_samples < 1:
            raise ValueError(
                f"SLO {self.name!r}: burn_threshold > 0, min_samples >= 1")


@dataclasses.dataclass(frozen=True)
class GaugeWatch:
    """Bounds on one recorder gauge (e.g. ``stream.replication_factor``).

    ``max_rel_increase`` is drift: the baseline is the gauge's value the
    first time the watcher sees it, and the alert fires when the value
    exceeds ``baseline * (1 + max_rel_increase)``.
    """
    gauge: str
    ceiling: float | None = None
    floor: float | None = None
    max_rel_increase: float | None = None

    def __post_init__(self):
        if self.ceiling is None and self.floor is None \
                and self.max_rel_increase is None:
            raise ValueError(
                f"GaugeWatch({self.gauge!r}): needs at least one bound")


class Monitor:
    """Evaluates SLO policies and health watchers over live telemetry.

    Feed it observations (``observe`` per served request — the
    ``GraphServer`` does this when constructed with ``monitor=``;
    ``observe_update_batch`` per stream apply — the adaptive compaction
    policy does), then ``evaluate()`` (or the rate-limited
    ``maybe_evaluate()``) to fire/clear alerts.  Registered as an
    ``obs`` snapshot provider, so ``obs.snapshot()`` shows live windowed
    percentiles and the active alert set next to the cache hierarchy.
    """

    def __init__(self, policies: tuple | list = (), *,
                 clock: Callable[[], float] = time.perf_counter,
                 slot_s: float = 1.0, slots: int = 120,
                 eval_interval_s: float = 0.25,
                 telemetry_window_s: float = 120.0):
        self.policies = tuple(policies)
        self._clock = clock
        self._slot_s = float(slot_s)
        self._slots = int(slots)
        self.eval_interval_s = float(eval_interval_s)
        self.telemetry_window_s = float(telemetry_window_s)
        self._series: dict[tuple[str, str], WindowedHistogram] = {}
        self._gauge_watches: list[GaugeWatch] = []
        self._gauge_baselines: dict[str, float] = {}
        self._retrace_watch: tuple[float, float] | None = None
        self._retrace_marks: deque[tuple[float, float]] = deque(maxlen=4096)
        self._updates: deque[tuple[float, int, int]] = deque(maxlen=4096)
        self._active: dict[tuple, dict] = {}
        self._last_eval = -float("inf")
        self.n_evaluations = 0
        self.n_alerts_fired = 0
        self.on_alert: list[Callable[[dict], None]] = []
        self._unregister = _get_recorder().register_provider(
            f"monitor{next(_MONITOR_IDS)}", self.stats)

    def close(self) -> None:
        self._unregister()

    # -- observations --------------------------------------------------------
    def _now(self, now: float | None) -> float:
        return self._clock() if now is None else float(now)

    def observe(self, tenant: str, program: str, latency_s: float,
                ok: bool = True, now: float | None = None) -> None:
        """One served (or shed) request: the SLO policies' raw material."""
        key = (str(tenant), str(program))
        series = self._series.get(key)
        if series is None:
            series = self._series[key] = WindowedHistogram(
                slot_s=self._slot_s, slots=self._slots)
        series.record(float(latency_s), ok=ok, now=self._now(now))

    def observe_update_batch(self, n_updates: int, slack_used: int,
                             dt_s: float = 0.0,
                             now: float | None = None) -> None:
        """One stream ``apply()``: feeds the update-rate / slack-burn
        telemetry the adaptive compaction policy sizes slack from.
        ``slack_used`` is the batch's inserted-edge count — the upper
        bound on per-partition slack slots it can have consumed."""
        self._updates.append((self._now(now), int(n_updates),
                              int(slack_used)))

    def _update_window(self, now: float | None = None
                       ) -> tuple[float, int, int, int]:
        """(span_s, n_updates, slack_used, peak_batch_slack) over the
        telemetry window."""
        t = self._now(now)
        lo = t - self.telemetry_window_s
        while self._updates and self._updates[0][0] < lo:
            self._updates.popleft()
        if not self._updates:
            return 0.0, 0, 0, 0
        span = max(t - self._updates[0][0], self._slot_s)
        return (span, sum(u[1] for u in self._updates),
                sum(u[2] for u in self._updates),
                max(u[2] for u in self._updates))

    def update_rate(self, now: float | None = None) -> float:
        """Observed edge updates per second over the telemetry window."""
        span, n, _, _ = self._update_window(now)
        return n / span if span > 0 else 0.0

    def slack_burn_rate(self, now: float | None = None) -> float:
        """Observed slack slots consumed per second (insert pressure)."""
        span, _, used, _ = self._update_window(now)
        return used / span if span > 0 else 0.0

    def peak_batch_slack(self, now: float | None = None) -> int:
        """Largest single-apply slack consumption in the window — the
        burst magnitude proactive headroom must absorb."""
        return self._update_window(now)[3]

    # -- watcher registration ------------------------------------------------
    def watch_gauge(self, watch: GaugeWatch) -> None:
        self._gauge_watches.append(watch)

    def watch_retrace_rate(self, max_per_s: float,
                           window_s: float = 30.0) -> None:
        self._retrace_watch = (float(max_per_s), float(window_s))

    # -- evaluation ----------------------------------------------------------
    def _burn(self, policy: SLOPolicy, series: WindowedHistogram,
              window_s: float, now: float) -> tuple[float, dict]:
        hist, n_fail = series.window(window_s, now)
        n = hist.n
        if n == 0:
            return 0.0, {"n": 0, "bad": 0}
        bad = n_fail + hist.count_above(policy.latency_objective_s)
        burn = (bad / n) / (1.0 - policy.availability_target)
        return burn, {"n": n, "bad": bad, "n_fail": n_fail,
                      "p50_s": hist.percentile(50),
                      "p99_s": hist.percentile(99)}

    def _transition(self, key: tuple, breached: bool, alert: dict,
                    fired: list[dict]) -> None:
        """Edge-triggered alert state machine: record + event + callbacks
        on fire, event on clear."""
        rec = _get_recorder()
        if breached and key not in self._active:
            self._active[key] = alert
            self.n_alerts_fired += 1
            rec.event("obs.alert", **alert)
            fired.append(alert)
            for cb in list(self.on_alert):
                cb(alert)
        elif not breached and key in self._active:
            cleared = self._active.pop(key)
            rec.event("obs.alert_clear",
                      kind=cleared["kind"], key=list(key))

    def evaluate(self, now: float | None = None) -> list[dict]:
        """Run every policy + watcher; returns newly fired alerts."""
        t = self._now(now)
        self._last_eval = t
        self.n_evaluations += 1
        fired: list[dict] = []
        # SLO burn rates: wildcard policies evaluate per concrete series
        for p in self.policies:
            for (tenant, program), series in list(self._series.items()):
                if not (fnmatch.fnmatchcase(tenant, p.tenant)
                        and fnmatch.fnmatchcase(program, p.program)):
                    continue
                key = ("burn_rate", p.name, tenant, program)
                burn_fast, wf = self._burn(p, series, p.fast_window_s, t)
                burn_slow, ws = self._burn(p, series, p.slow_window_s, t)
                enough = (wf["n"] >= p.min_samples
                          and ws["n"] >= p.min_samples)
                breached = (enough and burn_fast >= p.burn_threshold
                            and burn_slow >= p.burn_threshold)
                # clear needs only the fast window to recover (or drain)
                still = (key in self._active
                         and burn_fast >= p.burn_threshold and wf["n"] > 0)
                self._transition(key, breached or still, {
                    "kind": "burn_rate", "policy": p.name,
                    "tenant": tenant, "program": program,
                    "objective_s": p.latency_objective_s,
                    "availability_target": p.availability_target,
                    "threshold": p.burn_threshold,
                    "burn_fast": round(burn_fast, 3),
                    "burn_slow": round(burn_slow, 3),
                    "window": {"fast_s": p.fast_window_s,
                               "slow_s": p.slow_window_s,
                               "fast": wf, "slow": ws},
                }, fired)
        # gauge drift
        gauges = _get_recorder().gauges()
        for w in self._gauge_watches:
            value = gauges.get(w.gauge)
            if value is None:
                continue
            base = self._gauge_baselines.setdefault(w.gauge, float(value))
            reasons = []
            if w.ceiling is not None and value > w.ceiling:
                reasons.append(f"value {value:.4g} > ceiling {w.ceiling:.4g}")
            if w.floor is not None and value < w.floor:
                reasons.append(f"value {value:.4g} < floor {w.floor:.4g}")
            if w.max_rel_increase is not None and base > 0 \
                    and value > base * (1.0 + w.max_rel_increase):
                reasons.append(f"value {value:.4g} drifted "
                               f"{value / base - 1.0:+.1%} from baseline "
                               f"{base:.4g} (> +{w.max_rel_increase:.0%})")
            self._transition(("gauge", w.gauge), bool(reasons), {
                "kind": "gauge_drift", "gauge": w.gauge,
                "value": float(value), "baseline": base,
                "reasons": reasons,
                "window": {"gauges": {k: v for k, v in gauges.items()
                                      if k.startswith("stream.")}},
            }, fired)
        # retrace storms
        if self._retrace_watch is not None:
            max_per_s, window_s = self._retrace_watch
            count = float(_get_recorder().counters()
                          .get("engine.retraces", 0))
            self._retrace_marks.append((t, count))
            lo = t - window_s
            while len(self._retrace_marks) > 1 \
                    and self._retrace_marks[1][0] <= lo:
                self._retrace_marks.popleft()
            t0, c0 = self._retrace_marks[0]
            span = max(t - t0, self._slot_s)
            rate = max(count - c0, 0.0) / span
            self._transition(("retrace_rate",), rate > max_per_s, {
                "kind": "retrace_rate", "rate_per_s": round(rate, 3),
                "max_per_s": max_per_s,
                "window": {"window_s": window_s, "retraces": count - c0,
                           "span_s": round(span, 3)},
            }, fired)
        return fired

    def maybe_evaluate(self, now: float | None = None) -> list[dict]:
        """Rate-limited ``evaluate`` for hot paths (the serving drain)."""
        t = self._now(now)
        if t - self._last_eval < self.eval_interval_s:
            return []
        return self.evaluate(t)

    # -- introspection -------------------------------------------------------
    def active_alerts(self) -> list[dict]:
        return list(self._active.values())

    def stats(self) -> dict:
        """Live monitor state — registered as an ``obs`` provider."""
        t = self._now(None)
        return {
            "policies": [p.name for p in self.policies],
            "gauge_watches": [w.gauge for w in self._gauge_watches],
            "evaluations": self.n_evaluations,
            "alerts_fired": self.n_alerts_fired,
            "active_alerts": self.active_alerts(),
            "series": {
                f"{tenant}/{program}": s.stats(60.0, t)
                for (tenant, program), s in self._series.items()},
            "stream_telemetry": {
                "update_rate_per_s": round(self.update_rate(t), 3),
                "slack_burn_per_s": round(self.slack_burn_rate(t), 3),
                "peak_batch_slack": self.peak_batch_slack(t),
            },
        }

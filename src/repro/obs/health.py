"""Partition-health gauges derived from a compiled ``PartitionPlan``.

The paper judges a partitioning on replication factor, balance and
communication volume (§V-A); the streaming subsystem additionally lives or
dies by its remaining slack (how many more patches fit before a compaction
epoch forces a retrace).  ``plan_health`` computes all of them from the
plan's dynamic children so the stream session can stamp every installed
plan mutation with the live numbers, and ``obs.snapshot()`` always shows
the latest.

The formulas intentionally mirror ``core/metrics.py`` (``nstdev``,
``largest_norm``, replication factor = Σ|V_i| / |V|, exchange volume =
Σ|F_i| = MESSAGES) — tests/test_obs.py asserts the match — but this module
takes the *plan* as its input, not the graph + owner, so it stays a leaf
(no engine/core imports; any object with the plan's fields duck-types).

The result is memoized on the plan instance: plans are immutable pytrees
(every patch installs a new object), so health is computed at most once
per installed plan no matter how many dispatches or swap events read it.
"""
from __future__ import annotations

import numpy as np


def plan_health(plan) -> dict:
    """Health gauges for one compiled plan (memoized per plan instance)."""
    cached = plan.__dict__.get("_obs_health")
    if cached is not None:
        return cached
    sizes = np.asarray(plan.n_edges_local).astype(np.float64)   # [K]
    total = float(sizes.sum())
    k = int(plan.k)
    mean = total / k if total else 1.0
    norm = sizes / mean
    csr_fill = np.asarray(plan.csr_fill).astype(np.float64)     # [K]
    v_fill = np.asarray(plan.v_fill).astype(np.float64)         # [K]
    health = {
        # the paper's axes
        "replication_factor": float(plan.replication_factor()),
        "balance_nstdev": float(np.sqrt(np.mean((norm - 1.0) ** 2)))
                          if total else 0.0,
        "largest_norm": float(norm.max()) if total else 0.0,
        "exchange_per_superstep": int(plan.exchange_volume),
        # streaming slack: how far each partition is from forcing a
        # compaction epoch (and therefore a jit retrace)
        "edge_lane_occupancy_mean": float((csr_fill / plan.e_max).mean()),
        "edge_lane_occupancy_max": float((csr_fill / plan.e_max).max()),
        "vertex_lane_occupancy_mean": float((v_fill / plan.v_max).mean()),
        "vertex_lane_occupancy_max": float((v_fill / plan.v_max).max()),
        "min_free_edge_slots": int((plan.e_max - csr_fill).min()),
        "min_free_vertex_slots": int((plan.v_max - v_fill).min()),
    }
    object.__setattr__(plan, "_obs_health", health)
    return health

"""Mergeable per-tenant usage ledger: who spent which device resources.

``CostLedger`` joins the static per-sweep ``CostModel`` (obs.profile)
with measured execute-span durations: every completed dispatch posts one
``CostSample`` per request into a series keyed tenant × program × graph
× epoch.  Each series keeps a fixed-memory ``LogHistogram`` of
per-request device seconds plus monotone counters (device_s, flops, HBM
bytes, collective bytes, supersteps, requests, dispatched/cached
splits) and a utilization-weighted device-time sum, so "what does
tenant A's pagerank on graph G cost" is one dict lookup, and the whole
ledger stays O(active series) regardless of traffic.

The accounting invariant (held by tests and the gated ``fig_cost``
benchmark): per-tenant device-second totals sum to the total measured
execute-span time (±1%), and every dispatched request lands in exactly
one series.  Cache hits post zero-device-time samples (``from_cache``)
so request counts still reconcile.

Windowed shares — the admission-control signal — come from per-tenant
``WindowedHistogram`` rings recording device seconds against the
ledger's own monotonic clock: ``tenant_shares(window_s)`` normalizes the
trailing-window sums to fractions.  Ledgers ``merge()`` associatively
(histograms add, counters add) for multi-process roll-ups; windowed
rings are per-process and deliberately not merged.

A process-global ledger (``get_ledger()``) is registered as the
``"ledger"`` snapshot provider, so ``obs.snapshot()`` and every flight
bundle carry the usage breakdown automatically.  Explicit instances
(a per-server ledger under test) can be registered with
``register(ledger)``.  Render either with ``python -m repro.obs.usage``.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field

from . import recorder as _rec
from .histogram import LogHistogram, WindowedHistogram

SNAPSHOT_KIND = "cost_ledger"
DEFAULT_WINDOW_S = 60.0


@dataclass(frozen=True)
class CostSample:
    """One request's resolved cost: measured device time × modeled work.

    ``device_s`` is this request's slice of the measured execute-span
    wall time (an even split across the requests a batch served);
    ``flops``/``hbm_bytes``/``coll_bytes`` come from
    ``CostModel.cost(sweeps)`` split the same way.  ``utilization`` is
    achieved-vs-attainable: the roofline lower bound on the batch's
    device time divided by its measured time, in [0, 1] up to model
    error.  Cache hits post ``from_cache=True`` with zero device time so
    request accounting still balances.
    """

    tenant: str
    program: str
    graph: str
    epoch: int
    device_s: float
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    supersteps: int = 0
    n_requests: int = 1
    from_cache: bool = False
    utilization: float = 0.0


@dataclass
class _Series:
    """Monotone accumulators for one tenant × program × graph × epoch."""

    hist: LogHistogram = field(
        default_factory=lambda: LogHistogram(lo=1e-7, hi=1e4))
    device_s: float = 0.0
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    supersteps: int = 0
    requests: int = 0
    dispatched: int = 0
    cached: int = 0
    util_s: float = 0.0          # sum(utilization * device_s)

    def post(self, s: CostSample) -> None:
        self.hist.record(s.device_s)
        self.device_s += s.device_s
        self.flops += s.flops
        self.hbm_bytes += s.hbm_bytes
        self.coll_bytes += s.coll_bytes
        self.supersteps += int(s.supersteps)
        self.requests += int(s.n_requests)
        if s.from_cache:
            self.cached += int(s.n_requests)
        else:
            self.dispatched += int(s.n_requests)
        self.util_s += s.utilization * s.device_s

    def merge(self, other: "_Series") -> None:
        self.hist.merge(other.hist)
        self.device_s += other.device_s
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes += other.coll_bytes
        self.supersteps += other.supersteps
        self.requests += other.requests
        self.dispatched += other.dispatched
        self.cached += other.cached
        self.util_s += other.util_s

    def stats(self) -> dict:
        return {
            "device_s": self.device_s, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "coll_bytes": self.coll_bytes,
            "supersteps": self.supersteps, "requests": self.requests,
            "dispatched": self.dispatched, "cached": self.cached,
            "utilization": (self.util_s / self.device_s
                            if self.device_s > 0 else 0.0),
            "device_hist": self.hist.stats(),
        }


class CostLedger:
    """Thread-safe mergeable usage ledger with windowed per-tenant shares."""

    def __init__(self, window_s: float = DEFAULT_WINDOW_S):
        self.window_s = float(window_s)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._series: dict[tuple[str, str, str, int], _Series] = {}
        self._windows: dict[str, WindowedHistogram] = {}

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # -- recording -----------------------------------------------------------
    def post(self, sample: CostSample) -> None:
        key = (sample.tenant, sample.program, sample.graph,
               int(sample.epoch))
        with self._lock:
            series = self._series.get(key)
            if series is None:
                series = self._series[key] = _Series()
            series.post(sample)
            win = self._windows.get(sample.tenant)
            if win is None:
                win = self._windows[sample.tenant] = WindowedHistogram(
                    slot_s=0.5, slots=120, lo=1e-7, hi=1e4)
            win.record(sample.device_s, now=self._now())

    # -- queries -------------------------------------------------------------
    def totals(self) -> dict:
        with self._lock:
            return {
                "series": len(self._series),
                "device_s": sum(s.device_s for s in self._series.values()),
                "flops": sum(s.flops for s in self._series.values()),
                "hbm_bytes": sum(s.hbm_bytes
                                 for s in self._series.values()),
                "coll_bytes": sum(s.coll_bytes
                                  for s in self._series.values()),
                "requests": sum(s.requests for s in self._series.values()),
                "dispatched": sum(s.dispatched
                                  for s in self._series.values()),
                "cached": sum(s.cached for s in self._series.values()),
            }

    def tenant_shares(self, window_s: float | None = None
                      ) -> dict[str, float]:
        """Per-tenant fraction of device time over the trailing
        ``window_s`` seconds (the admission signal); ``None``/``0`` uses
        lifetime totals."""
        with self._lock:
            if window_s:
                now = self._now()
                spent = {t: w.window(float(window_s), now)[0].total
                         for t, w in self._windows.items()}
            else:
                spent = {}
                for (tenant, _, _, _), s in self._series.items():
                    spent[tenant] = spent.get(tenant, 0.0) + s.device_s
        total = sum(spent.values())
        if total <= 0:
            return {t: 0.0 for t in spent}
        return {t: v / total for t, v in spent.items()}

    def snapshot(self) -> dict:
        """Structured record for obs.snapshot()/flight bundles/usage.py."""
        with self._lock:
            series = [
                {"tenant": t, "program": p, "graph": g, "epoch": e,
                 **s.stats()}
                for (t, p, g, e), s in sorted(self._series.items())
            ]
        shares = self.tenant_shares(self.window_s)
        tenants: dict[str, dict] = {}
        for row in series:
            agg = tenants.setdefault(row["tenant"], {
                "device_s": 0.0, "flops": 0.0, "hbm_bytes": 0.0,
                "coll_bytes": 0.0, "requests": 0, "dispatched": 0,
                "cached": 0, "util_s": 0.0})
            for k in ("device_s", "flops", "hbm_bytes", "coll_bytes",
                      "requests", "dispatched", "cached"):
                agg[k] += row[k]
            agg["util_s"] += row["utilization"] * row["device_s"]
        for t, agg in tenants.items():
            util_s = agg.pop("util_s")
            agg["utilization"] = (util_s / agg["device_s"]
                                  if agg["device_s"] > 0 else 0.0)
            agg["window_share"] = shares.get(t, 0.0)
        return {"kind": SNAPSHOT_KIND, "version": 1,
                "window_s": self.window_s, "totals": self.totals(),
                "tenants": tenants, "series": series}

    # -- lifecycle -----------------------------------------------------------
    def merge(self, other: "CostLedger") -> "CostLedger":
        """Fold another ledger's series in place (multi-process roll-up).
        Windowed rings stay local — shares only mean anything against one
        process's clock."""
        with other._lock:
            items = [(k, s) for k, s in other._series.items()]
        with self._lock:
            for key, s in items:
                mine = self._series.get(key)
                if mine is None:
                    mine = self._series[key] = _Series()
                mine.merge(s)
        return self

    def reset(self) -> None:
        with self._lock:
            self._series.clear()
            self._windows.clear()
            self._t0 = time.perf_counter()

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)


_GLOBAL = CostLedger()


def get_ledger() -> CostLedger:
    """The process-global ledger (default sink when gserve has no
    explicit one)."""
    return _GLOBAL


def register(ledger: CostLedger, name: str = "ledger"):
    """Expose a ledger in obs.snapshot() / flight bundles; returns the
    unregister callable."""
    return _rec.get().register_provider(name, ledger.snapshot)


register(_GLOBAL)

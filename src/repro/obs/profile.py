"""Static per-executable cost models from post-optimization HLO.

The attribution layer's *price list*: for each (program, plan
fingerprint, batch bucket) the engine can dispatch, lower the exact
executable once via ``Engine.lower_hlo`` and run the roofline analyzer
(``repro.roofline.hlo_parse``) over the optimized HLO with
``trip_clamp=1`` — yielding **per-sweep** costs (one superstep /
local-iteration body) that are scaled at sample time by the measured
number of sweeps actually run.  The result is a frozen ``CostModel``
(flops, HBM bytes, collective bytes, arithmetic intensity) memoized in a
module-level LRU keyed by everything that changes the lowered
executable: program name, the plan's static aux (k, n_vertices, v_max,
e_max, epoch, e_slots), sharded-or-not, the serve bucket, and the
shape/dtype signature of ctx and batched arguments.  ``max_supersteps``
and warm-start state are deliberately NOT part of the key — they change
trip counts and initial values, never the per-sweep cost.

Profiling must never break serving: every failure mode (lowering error,
analyzer error, malformed HLO) degrades to an *error model* with zero
costs and the exception recorded in ``CostModel.error``; ``cost_model``
never raises.  Cache hits/misses/errors are a registered obs provider
(``snapshot()["cost_models"]``), and each fresh compile records a
``profile.compile`` event when the recorder is enabled.

This module must not import ``repro.engine`` (the engine imports
``repro.obs``); it duck-types the engine instance through its ``plan``,
``mesh`` and ``lower_hlo`` attributes.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any

from ..roofline.hlo_parse import analyze_hlo
from . import recorder as _rec

# Nominal device peaks for achieved-vs-attainable utilization.  These are
# deliberately env-tunable *nominals*, not measured values: utilization is
# a comparable ranking signal across tenants on the same host, not an
# absolute hardware-efficiency claim.
PEAK_FLOPS = float(os.environ.get("REPRO_PEAK_FLOPS", 5e10))
PEAK_HBM_BPS = float(os.environ.get("REPRO_PEAK_BW", 2e10))

_CACHE_CAP = 256


@dataclass(frozen=True)
class CostModel:
    """Per-sweep static cost of one compiled executable.

    ``flops_per_sweep`` / ``hbm_bytes_per_sweep`` / ``coll_bytes_per_sweep``
    are the analyzer's totals with every loop clamped to one trip; multiply
    by the measured sweep count (``cost()``) to price a dispatch.  An
    ``error`` model (all costs zero, ``error`` set) is what a failed
    lowering degrades to — samples priced by it carry device time but no
    flop/byte attribution.
    """

    program: str
    plan_key: tuple
    bucket: int | None
    sharded: bool
    flops_per_sweep: float
    hbm_bytes_per_sweep: float
    coll_bytes_per_sweep: float
    unmodeled_ops: int = 0
    hlo_chars: int = 0
    compile_s: float = 0.0
    error: str | None = None

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_sweep / max(self.hbm_bytes_per_sweep, 1.0)

    def cost(self, sweeps: int) -> tuple[float, float, float]:
        """(flops, hbm_bytes, coll_bytes) for a dispatch that ran
        ``sweeps`` superstep/local-iteration bodies."""
        s = max(int(sweeps), 1)
        return (self.flops_per_sweep * s, self.hbm_bytes_per_sweep * s,
                self.coll_bytes_per_sweep * s)

    def attainable_s(self, sweeps: int) -> float:
        """Roofline lower bound on device time for ``sweeps`` sweeps: the
        slower of the compute and memory ceilings (collective bytes ride
        the HBM term — a deliberate single-node simplification)."""
        fl, by, _ = self.cost(sweeps)
        return max(fl / PEAK_FLOPS, by / PEAK_HBM_BPS)


_LOCK = threading.Lock()
_MODELS: OrderedDict[tuple, CostModel] = OrderedDict()
_STATS = {"hits": 0, "misses": 0, "errors": 0}


def _shape_sig(kw: dict | None) -> tuple:
    if not kw:
        return ()
    out = []
    for k in sorted(kw):
        v = kw[k]
        shape = tuple(getattr(v, "shape", ()))
        dtype = str(getattr(v, "dtype", type(v).__name__))
        out.append((k, shape, dtype))
    return tuple(out)


def _plan_key(plan: Any) -> tuple:
    return (plan.k, plan.n_vertices, plan.v_max, plan.e_max, plan.epoch,
            plan.e_slots)


def cost_model(engine: Any, prog: Any, *, bucket: int | None = None,
               batched_kw: dict | None = None,
               max_supersteps: int | None = None, **kw: Any) -> CostModel:
    """The memoized per-sweep ``CostModel`` for one dispatchable executable.

    ``engine`` is duck-typed (``plan``, ``mesh``, ``lower_hlo``); ``prog``
    needs only ``.name``.  Never raises — failures return an error model
    (also cached, so a persistently broken lowering is paid for once).
    """
    key = (getattr(prog, "name", str(prog)), _plan_key(engine.plan),
           engine.mesh is not None, bucket, _shape_sig(kw),
           _shape_sig(batched_kw))
    with _LOCK:
        model = _MODELS.get(key)
        if model is not None:
            _MODELS.move_to_end(key)
            _STATS["hits"] += 1
            return model
        _STATS["misses"] += 1

    import time
    t0 = time.perf_counter()
    try:
        hlo = engine.lower_hlo(prog, batched_kw=batched_kw,
                               max_supersteps=max_supersteps, **kw)
        costs = analyze_hlo(hlo, trip_clamp=1)
        model = CostModel(
            program=key[0], plan_key=key[1], bucket=bucket,
            sharded=key[2], flops_per_sweep=costs.flops,
            hbm_bytes_per_sweep=costs.bytes_traffic,
            coll_bytes_per_sweep=costs.coll_bytes,
            unmodeled_ops=costs.unmodeled_ops, hlo_chars=len(hlo),
            compile_s=time.perf_counter() - t0)
    except Exception as e:  # noqa: BLE001 — profiling never breaks serving
        model = CostModel(
            program=key[0], plan_key=key[1], bucket=bucket,
            sharded=key[2], flops_per_sweep=0.0, hbm_bytes_per_sweep=0.0,
            coll_bytes_per_sweep=0.0,
            compile_s=time.perf_counter() - t0,
            error=f"{type(e).__name__}: {e}")
        with _LOCK:
            _STATS["errors"] += 1

    with _LOCK:
        _MODELS[key] = model
        while len(_MODELS) > _CACHE_CAP:
            _MODELS.popitem(last=False)

    rec = _rec.get()
    if rec.enabled:
        rec.event("profile.compile", program=model.program,
                  bucket=bucket, flops_per_sweep=model.flops_per_sweep,
                  hbm_bytes_per_sweep=model.hbm_bytes_per_sweep,
                  unmodeled_ops=model.unmodeled_ops,
                  compile_s=round(model.compile_s, 4),
                  error=model.error)
    return model


def profile_stats() -> dict:
    with _LOCK:
        return {"size": len(_MODELS), **_STATS}


def reset_models() -> None:
    """Drop all memoized models and zero the stats (tests)."""
    with _LOCK:
        _MODELS.clear()
        for k in _STATS:
            _STATS[k] = 0


_rec.get().register_provider("cost_models", profile_stats)

"""Process-global observability recorder: events, spans, counters, gauges.

One ``Recorder`` instance per process (``get()``), shared by every
subsystem — partition plan compilation, the superstep engine, the
streaming session, and the serving layer all record into the same
fixed-size ring buffer, so one exported trace follows a served request
from admission through batch formation, dispatch, device execution and
host materialisation, interleaved with the stream mutations and jit
retraces that happened around it.

Overhead contract
-----------------
The recorder is DISABLED by default.  Every recording method begins with
``if not self._enabled: return`` — one predictable branch, no allocation
inside the recorder.  Hot call sites (per-dispatch, per-request) guard
with ``if rec.enabled:`` before building keyword arguments, so a disabled
recorder costs one attribute read per potential event.  When enabled,
recording one event is a dict build plus a ring-slot assignment — no I/O,
no locks on the record path (CPython list-item assignment is atomic under
the GIL; a racing pair of writers can at worst overwrite one slot, never
corrupt the ring).  ``benchmarks/fig_obs.py`` holds the enabled-vs-
disabled serving overhead under 3% qps in CI.

Ring buffer
-----------
``capacity`` slots, overwritten oldest-first.  ``stats()["recorded"]`` is
a lifetime monotonic count (survives ``reset()``); ``dropped`` counts
events that have been overwritten since the last reset, and
``overwritten`` is the lifetime monotone overwrite count — the silent-
data-loss meter (``benchmarks/run.py --all`` prints its per-figure
delta).

Spans
-----
``begin(name, parent=..., **args) -> span_id`` / ``end(span_id, **extra)``
record a complete-span event (Chrome ``"X"`` phase) at *end* time with its
measured duration.  ``parent`` defaults to the innermost open span on the
current thread (``span()`` context manager maintains that stack), but can
be passed explicitly — the serving layer's software-pipelined drain
interleaves batches, so its child spans carry explicit parent ids.
``args["span_id"]`` / ``args["parent_id"]`` make the tree reconstructable
from an exported trace.

Ambient tags
------------
``with rec.tags(program="sssp", bucket=16): ...`` merges key/values into
every event recorded on the thread inside the block — how a jit retrace
deep inside the engine gets attributed to the dispatch (program, bucket
shape) that triggered it without threading arguments through jax.

Providers
---------
``register_provider(name, fn)`` attaches a live stats source (the serving
metrics, the plan cache, the jit trace counters).  ``snapshot()`` calls
each one so a single call shows the whole hierarchy: result cache ->
plan cache -> jit cache -> device.  Bound methods are held by weakref —
a garbage-collected server drops out of the snapshot instead of leaking.
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import time
import weakref
from typing import Any, Callable


class Recorder:
    """Fixed-size ring buffer of structured events and spans."""

    def __init__(self, capacity: int = 8192):
        self._capacity = int(capacity)
        self._enabled = False
        self._providers: dict[str, Any] = {}
        self._span_ids = itertools.count(1)
        self._local = threading.local()
        self._lock = threading.Lock()    # guards enable/reset/export only
        self._lifetime = 0               # events ever recorded (never reset)
        self._overwritten = 0            # events ever lost to ring
                                         #   wraparound (monotone, never
                                         #   reset — silent data loss must
                                         #   stay visible across resets)
        self._reset_state()

    def _reset_state(self) -> None:
        self._ring: list = [None] * self._capacity
        self._n = 0                      # ring write index since last reset
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._by_name: dict[str, int] = {}
        self._open: dict[int, dict] = {}
        self._t0 = time.perf_counter()

    # -- lifecycle -----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, capacity: int | None = None) -> None:
        with self._lock:
            if capacity is not None and int(capacity) != self._capacity:
                self._capacity = int(capacity)
                self._reset_state()
            self._enabled = True

    def disable(self) -> None:
        """Stop recording; already-recorded events stay exportable."""
        self._enabled = False

    def reset(self) -> None:
        """Drop recorded events/counters/gauges (the lifetime count and the
        registered providers survive — ``benchmarks/run.py`` attributes
        events per figure from lifetime deltas across resets)."""
        with self._lock:
            self._reset_state()

    # -- recording (no-op fast path: one branch when disabled) ---------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _record(self, rec: dict) -> None:
        i = self._n
        self._n = i + 1
        self._lifetime += 1
        if i >= self._capacity:          # this write evicts the oldest event
            self._overwritten += 1
        self._ring[i % self._capacity] = rec
        name = rec["name"]
        self._by_name[name] = self._by_name.get(name, 0) + 1

    def _merge_tags(self, args: dict) -> dict:
        stack = getattr(self._local, "tags", None)
        if not stack:
            return args
        merged: dict = {}
        for t in stack:
            merged.update(t)
        merged.update(args)
        return merged

    def event(self, name: str, **args: Any) -> None:
        """Record one instant event (Chrome phase ``"i"``)."""
        if not self._enabled:
            return
        self._record({"name": name, "ph": "i", "ts": self._now_us(),
                      "tid": threading.get_ident(),
                      "args": self._merge_tags(args)})

    def counter(self, name: str, delta: float = 1) -> None:
        if not self._enabled:
            return
        self._counters[name] = self._counters.get(name, 0) + delta

    def gauge(self, name: str, value: float) -> None:
        if not self._enabled:
            return
        self._gauges[name] = value

    # -- spans ---------------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def begin(self, name: str, parent: int | None = None,
              **args: Any) -> int | None:
        """Open a span; returns its id (None when disabled — ``end(None)``
        is a no-op, so call sites need no second branch)."""
        if not self._enabled:
            return None
        sid = next(self._span_ids)
        if parent is None:
            stack = getattr(self._local, "stack", None)
            if stack:
                parent = stack[-1]
        a = self._merge_tags(args)
        a["span_id"] = sid
        if parent is not None:
            a["parent_id"] = parent
        self._open[sid] = {"name": name, "ph": "X", "ts": self._now_us(),
                           "dur": 0.0, "tid": threading.get_ident(),
                           "args": a}
        return sid

    def end(self, span_id: int | None, **extra: Any) -> None:
        """Close a span (recording it, with duration); merges ``extra`` into
        its args — values only known at completion (supersteps, cache
        hits) attach to the span that produced them."""
        if span_id is None:
            return
        rec = self._open.pop(span_id, None)
        if rec is None:
            return
        rec["dur"] = self._now_us() - rec["ts"]
        if extra:
            rec["args"].update(extra)
        self._record(rec)

    @contextlib.contextmanager
    def span(self, name: str, parent: int | None = None, **args: Any):
        """Context-managed span; nests via a per-thread stack (children
        opened inside default their parent to this span)."""
        if not self._enabled:
            yield None
            return
        sid = self.begin(name, parent=parent, **args)
        stack = self._stack()
        stack.append(sid)
        try:
            yield sid
        finally:
            stack.pop()
            self.end(sid)

    @contextlib.contextmanager
    def tags(self, **tags: Any):
        """Ambient tags: merged into every event/span recorded on this
        thread inside the block (explicit args win on key collision)."""
        if not self._enabled:
            yield
            return
        stack = getattr(self._local, "tags", None)
        if stack is None:
            stack = self._local.tags = []
        stack.append(tags)
        try:
            yield
        finally:
            stack.pop()

    # -- introspection -------------------------------------------------------
    def events(self) -> list[dict]:
        """Recorded events, oldest first (ring contents since last reset)."""
        n, cap = self._n, self._capacity
        if n <= cap:
            return [e for e in self._ring[:n] if e is not None]
        head = n % cap
        return [e for e in self._ring[head:] + self._ring[:head]
                if e is not None]

    def stats(self) -> dict:
        return {"enabled": self._enabled, "capacity": self._capacity,
                "recorded": self._lifetime,
                "since_reset": self._n,
                "dropped": max(0, self._n - self._capacity),
                "overwritten": self._overwritten,
                "open_spans": len(self._open)}

    def gauges(self) -> dict[str, float]:
        """Latest gauge values (a copy) — the monitor's watchers read these
        without paying ``snapshot()``'s provider calls."""
        return dict(self._gauges)

    def counters(self) -> dict[str, float]:
        """Current counter values (a copy)."""
        return dict(self._counters)

    # -- providers + snapshot ------------------------------------------------
    def register_provider(self, name: str, fn: Callable[[], dict]
                          ) -> Callable[[], None]:
        """Attach a stats source to ``snapshot()``; returns an unregister
        callable.  Bound methods are stored as weakrefs so a dead owner
        (an un-closed GraphServer) silently drops out."""
        if hasattr(fn, "__self__"):
            self._providers[name] = weakref.WeakMethod(fn)
        else:
            self._providers[name] = fn

        def unregister() -> None:
            self._providers.pop(name, None)
        return unregister

    def snapshot(self) -> dict:
        """One structured record of everything the recorder knows: ring
        stats, counters, gauges (latest partition-health values from the
        stream), per-name event counts, and every registered provider's
        live stats — the full cache hierarchy in one call."""
        out = dict(self.stats())
        out["counters"] = dict(self._counters)
        out["gauges"] = dict(self._gauges)
        out["events_by_name"] = dict(self._by_name)
        for name in list(self._providers):
            fn = self._providers[name]
            if isinstance(fn, weakref.WeakMethod):
                live = fn()
                if live is None:                 # owner collected: prune
                    self._providers.pop(name, None)
                    continue
                fn = live
            # one broken provider must not abort the whole snapshot — it
            # is exactly the degraded state a postmortem snapshot is FOR
            try:
                out[name] = fn()
            except Exception as e:               # noqa: BLE001
                out[name] = {"error": f"{type(e).__name__}: {e}"}
        return out


_RECORDER = Recorder()


def get() -> Recorder:
    """The process-global recorder every subsystem records into."""
    return _RECORDER

"""Config registry: one module per assigned architecture (+ smoke variants)."""
from __future__ import annotations

import importlib

from .base import SHAPES, MlaConfig, ModelConfig, MoeConfig, ShapeConfig, SsmConfig  # noqa: F401

ARCHS = (
    "jamba_v01_52b",
    "falcon_mamba_7b",
    "qwen3_4b",
    "qwen2_1_5b",
    "granite_3_2b",
    "qwen3_0_6b",
    "llava_next_34b",
    "whisper_small",
    "qwen2_moe_a2_7b",
    "deepseek_v2_236b",
)

# canonical external ids (--arch <id>)
ARCH_IDS = {
    "jamba-v0.1-52b": "jamba_v01_52b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-1.5b": "qwen2_1_5b",
    "granite-3-2b": "granite_3_2b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llava-next-34b": "llava_next_34b",
    "whisper-small": "whisper_small",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ARCH_IDS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCH_IDS)

"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE every other
layer [arXiv:2403.19887]. Hybrid (mostly SSM) -> long_500k RUNS (its 4
attention layers use the sequence-sharded cache)."""
from .base import ModelConfig, MoeConfig, SsmConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=65536, d_head=128,
    attn_period=8,
    moe=MoeConfig(n_experts=16, top_k=2, every=2),
    ssm=SsmConfig(d_state=16, d_conv=4, expand=2), sub_quadratic=True)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=8, d_model=128, n_heads=4,
    n_kv=2, d_ff=256, vocab=512, d_head=32, attn_period=4,
    moe=MoeConfig(n_experts=4, top_k=2, every=2),
    ssm=SsmConfig(d_state=8, d_conv=4, expand=2), sub_quadratic=True)

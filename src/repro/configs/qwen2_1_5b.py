"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
— GQA, QKV bias [arXiv:2407.10671; hf]. Full attention -> long_500k skipped.
Note: 12 q-heads pad to 16 on the tp=16 mesh (DESIGN.md §5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536, n_heads=12,
    n_kv=2, d_ff=8960, vocab=151936, d_head=128, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, d_head=32, qkv_bias=True,
    tie_embeddings=True)

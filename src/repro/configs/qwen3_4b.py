"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936
— qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]. Full attention -> long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560, n_heads=32,
    n_kv=8, d_ff=9728, vocab=151936, d_head=128, qk_norm=True,
    rope_theta=1e6)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv=2, d_ff=256, vocab=512, d_head=32, qk_norm=True)

"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865 — enc-dec, conv frontend STUB (input_specs() provides
precomputed frame embeddings, 1500 frames = 30 s) [arXiv:2212.04356].
Enc-dec (not encoder-only) -> decode shapes run on the decoder.
12 heads pad to 16 (MHA) on tp=16. Full attention -> long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv=12, d_ff=3072, vocab=51865, d_head=64,
    n_enc_layers=12, enc_seq=1500)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=128,
    n_heads=4, n_kv=4, d_ff=256, vocab=512, d_head=32,
    n_enc_layers=2, enc_seq=64)

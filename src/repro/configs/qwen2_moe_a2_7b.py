"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, 60 routed top-4 + shared expert (4x1408 wide)
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 60 experts pad to 64 on tp=16 (dead experts
masked in the router). Full attention -> long_500k skipped."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv=16, d_ff=5632, vocab=151936, d_head=128, qkv_bias=True,
    moe=MoeConfig(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408,
                  every=1))

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe", n_layers=4, d_model=128, n_heads=4,
    n_kv=4, d_ff=256, vocab=512, d_head=32, qkv_bias=True,
    moe=MoeConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=64, every=1))

"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]. long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024, n_heads=16,
    n_kv=8, d_ff=3072, vocab=151936, d_head=128, qk_norm=True,
    tie_embeddings=True, rope_theta=1e6)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, d_head=32, qk_norm=True,
    tie_embeddings=True)

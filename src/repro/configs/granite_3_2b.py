"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf].
vocab pads 49155 -> 49280 for the tp=16 mesh. long_500k skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv=8, d_ff=8192, vocab=49155, d_head=64,
    tie_embeddings=True)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=515, d_head=32, tie_embeddings=True)

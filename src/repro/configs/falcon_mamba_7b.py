"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free, ssm_state=16 —
mamba1 arch [arXiv:2410.05355]. Sub-quadratic -> long_500k RUNS."""
from .base import ModelConfig, SsmConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv=1, d_ff=0, vocab=65024, d_head=64,
    ssm=SsmConfig(d_state=16, d_conv=4, expand=2), sub_quadratic=True)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm", n_layers=4, d_model=128,
    n_heads=1, n_kv=1, d_ff=0, vocab=512, d_head=32,
    ssm=SsmConfig(d_state=8, d_conv=4, expand=2), sub_quadratic=True)

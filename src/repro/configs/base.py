"""Model / run configuration system.

One ``ModelConfig`` dataclass covers the whole assigned architecture pool
(dense GQA, MoE, MLA, SSM, hybrid, enc-dec, VLM-stub). Every architecture
file in this package exports ``CONFIG`` (full size, dry-run only) and
``SMOKE`` (reduced, runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts
    d_ff_expert: int = 0      # per-expert FFN width (0 -> use model d_ff)
    every: int = 1            # MoE every Nth layer (others dense)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    kv_lora: int              # compressed KV dim (c_kv)
    q_lora: int = 0           # 0 -> no query compression
    rope_head_dim: int = 64   # decoupled RoPE key/query dim
    v_head_dim: int = 128
    nope_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False           # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False          # qwen2-style
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoeConfig | None = None
    mla: MlaConfig | None = None
    ssm: SsmConfig | None = None
    # hybrid (jamba): one attention layer per `attn_period` layers, rest SSM
    attn_period: int = 0            # 0 -> all layers attention (or all SSM)
    # enc-dec (whisper): encoder depth + stub frontend sequence length
    n_enc_layers: int = 0
    enc_seq: int = 1500             # precomputed audio-frame embeddings
    # vlm (llava): stub frontend provides precomputed patch embeddings
    n_img_tokens: int = 0
    # notes for DESIGN.md §Arch-applicability
    sub_quadratic: bool = False     # can run long_500k

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def layer_pattern(self) -> tuple[str, ...]:
        """Kinds of the layers inside one scanned block (DESIGN: scan over
        repeated blocks keeps the lowered HLO small)."""
        if self.family == "ssm":
            return ("ssm",)
        if self.family == "hybrid":
            assert self.attn_period > 0
            pat = ["ssm"] * self.attn_period
            pat[self.attn_period // 2] = "attn"   # jamba puts attn mid-block
            return tuple(pat)
        return ("attn",)

    @property
    def block_repeats(self) -> int:
        pat = len(self.layer_pattern)
        assert self.n_layers % pat == 0, (self.n_layers, pat)
        return self.n_layers // pat

    def moe_at(self, layer_idx: int) -> bool:
        """Is this layer's FFN an MoE block?"""
        if self.moe is None:
            return False
        return (layer_idx % self.moe.every) == (self.moe.every - 1)

    def ffn_kind(self, layer_idx: int) -> str:
        """'moe' | 'dense' | 'none' for this layer's FFN component."""
        if self.moe_at(layer_idx):
            return "moe"
        return "dense" if self.d_ff > 0 else "none"

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv, self.head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for li in range(self.n_layers):
            kind = self.layer_pattern[li % len(self.layer_pattern)]
            if kind == "ssm":
                s = self.ssm or SsmConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += (d * 2 * d_in + d_in * s.d_conv
                          + d_in * (dt_rank + 2 * s.d_state)
                          + dt_rank * d_in + d_in * s.d_state + d_in
                          + d_in * d)
            elif self.mla is not None:
                m = self.mla
                q_in = m.q_lora or d
                total += d * m.kv_lora + d * m.rope_head_dim
                if m.q_lora:
                    total += d * m.q_lora
                total += q_in * h * (m.nope_head_dim + m.rope_head_dim)
                total += m.kv_lora * h * (m.nope_head_dim + m.v_head_dim)
                total += h * m.v_head_dim * d
            else:
                total += d * h * dh + 2 * d * kv * dh + h * dh * d
            fk = self.ffn_kind(li)
            if fk == "moe":
                mo = self.moe
                fe = mo.d_ff_expert or f
                total += d * mo.n_experts  # router
                total += (mo.n_experts + mo.n_shared) * 3 * d * fe
            elif fk == "dense":
                total += 3 * d * f
        # encoder layers (whisper): bidirectional attn + dense FFN; decoder
        # layers above additionally carry cross-attention
        if self.n_enc_layers:
            total += self.n_enc_layers * (d * h * dh + 2 * d * kv * dh
                                          + h * dh * d + 3 * d * f)
            total += self.n_layers * (d * h * dh + 2 * d * kv * dh + h * dh * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        mo = self.moe
        fe = mo.d_ff_expert or f
        n_moe_layers = sum(1 for li in range(self.n_layers) if self.moe_at(li))
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * 3 * d * fe
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}

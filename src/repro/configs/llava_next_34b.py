"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].
Modality frontend is a STUB: input_specs() provides precomputed patch
embeddings (anyres tiling happens upstream). 56 q-heads pad to 64 on tp=16.
Full attention -> long_500k skipped."""
from .base import ModelConfig

N_IMG_TOKENS = 2880  # anyres: base 576 + 4 tiles x 576

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv=8, d_ff=20480, vocab=64000, d_head=128,
    n_img_tokens=N_IMG_TOKENS)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm", n_layers=4, d_model=128, n_heads=4,
    n_kv=2, d_ff=256, vocab=512, d_head=32, n_img_tokens=16)

"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]. first_k_dense_replace=1 approximated as MoE throughout
for scan homogeneity (+0.03% params; DESIGN.md §7). Full attention ->
long_500k skipped."""
from .base import MlaConfig, ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv=128, d_ff=12288, vocab=102400, d_head=192,
    mla=MlaConfig(kv_lora=512, q_lora=1536, rope_head_dim=64,
                  v_head_dim=128, nope_head_dim=128),
    moe=MoeConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  every=1))

SMOKE = ModelConfig(
    name="deepseek-v2-smoke", family="moe", n_layers=4, d_model=128,
    n_heads=4, n_kv=4, d_ff=256, vocab=512, d_head=48,
    mla=MlaConfig(kv_lora=64, q_lora=96, rope_head_dim=16, v_head_dim=32,
                  nope_head_dim=32),
    moe=MoeConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=64, every=1))

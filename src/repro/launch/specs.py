"""input_specs(): ShapeDtypeStruct stand-ins + logical shardings for every
(arch × shape) cell — weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..configs.base import SHAPES, ModelConfig, ShapeConfig
from ..models import lm
from ..sharding.env import get_env, logical_spec
from ..train.optimizer import OptState

SD = jax.ShapeDtypeStruct


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 524k-token decode requires "
                "sub-quadratic attention (DESIGN.md §5)")
    return None


def param_structs(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, logical spec tree) without allocating: trace
    init_params abstractly, capturing the (static) spec tree on the side."""
    captured: dict[str, Any] = {}

    def f(k):
        p, s = lm.init_params(cfg, k)
        captured["s"] = s
        return p

    structs = jax.eval_shape(f, jax.random.key(0))
    return structs, captured["s"]


def batch_structs(cfg: ModelConfig, shape: ShapeConfig):
    """Training/prefill batch stand-ins."""
    b = shape.global_batch
    s = shape.seq_len
    s_text = s - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    structs: dict[str, Any] = {
        "tokens": SD((b, s_text), jnp.int32),
        "labels": SD((b, s_text), jnp.int32),
    }
    specs: dict[str, Any] = {
        "tokens": ("dp", None),
        "labels": ("dp", None),
    }
    if cfg.family == "vlm":
        structs["img_embeds"] = SD((b, cfg.n_img_tokens, cfg.d_model),
                                   jnp.bfloat16)
        specs["img_embeds"] = ("dp", None, None)
    if cfg.family == "encdec":
        structs["enc_frames"] = SD((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        specs["enc_frames"] = ("dp", None, None)
    return structs, specs


def input_specs(arch: str, shape_name: str):
    """Everything dryrun needs for one cell: callable + arg structs/specs.

    Returns dict(fn_kind, cfg, structs (tuple), logical spec trees).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"skip": reason, "cfg": cfg, "shape": shape}

    p_structs, p_specs = param_structs(cfg)

    if shape.kind != "train":
        from ..models.perf import get_perf
        perf = get_perf()
        if perf.serve_bf16:   # §Perf: serve in bf16 (halves weight traffic)
            p_structs = jax.tree.map(
                lambda s: SD(s.shape, jnp.bfloat16)
                if jnp.issubdtype(s.dtype, jnp.floating) else s, p_structs)
        if perf.serve_replicate_dp_below_gb > 0:
            # §Perf: replicate weights across dp when the tp-sharded copy
            # fits — removes per-layer FSDP all-gathers from the decode path.
            # Only pays when the batch cannot shard over dp (B < dp) and the
            # arch is attention-bearing (weight gathers dwarf cache reads);
            # measured regressions otherwise (EXPERIMENTS.md §Perf iter. 9).
            total = sum(s.size * s.dtype.itemsize
                        for s in jax.tree.leaves(p_structs))
            per_dev_gb = total / max(get_env().tp_size(), 1) / 2**30
            has_attn = ("attn" in cfg.layer_pattern) or cfg.mla is not None
            small_batch = shape.global_batch < max(get_env().dp_size(), 1)
            if (per_dev_gb <= perf.serve_replicate_dp_below_gb
                    and has_attn and small_batch):
                def drop_fsdp(spec):
                    return tuple(None if part == "fsdp" else part
                                 for part in spec)
                p_specs = jax.tree.map(
                    drop_fsdp, p_specs,
                    is_leaf=lambda x: isinstance(x, tuple)
                    and all(e is None or isinstance(e, (str, tuple))
                            for e in x))

    out = {"cfg": cfg, "shape": shape, "skip": None,
           "params": (p_structs, p_specs)}

    if shape.kind == "train":
        b_structs, b_specs = batch_structs(cfg, shape)
        opt_structs = OptState(
            SD((), jnp.int32),
            jax.tree.map(lambda x: SD(x.shape, x.dtype), p_structs),
            jax.tree.map(lambda x: SD(x.shape, x.dtype), p_structs))
        opt_specs = OptState((), p_specs, p_specs)
        out["batch"] = (b_structs, b_specs)
        out["opt"] = (opt_structs, opt_specs)
    elif shape.kind == "prefill":
        b_structs, b_specs = batch_structs(cfg, shape)
        del b_structs["labels"], b_specs["labels"]
        out["batch"] = (b_structs, b_specs)
    else:  # decode
        b = shape.global_batch
        cache_structs, cache_specs = lm.cache_struct(cfg, b, shape.seq_len)
        out["token"] = (SD((b, 1), jnp.int32),
                        ("dp" if b >= get_env().dp_size() and
                         b % max(get_env().dp_size(), 1) == 0 and
                         get_env().dp_size() > 1 else None, None))
        out["caches"] = (cache_structs, cache_specs)
        if cfg.family == "encdec":
            x_structs, x_specs = lm.cross_kv_struct(cfg, b)
            out["cross"] = (x_structs, x_specs)
    return out

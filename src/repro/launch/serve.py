"""Production serving launcher: mesh + sharded weights + batched engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --n-new 8
"""
from __future__ import annotations

import argparse
import os

import jax

from ..configs import get_config
from ..models import lm
from ..models.perf import TUNED, set_perf
from ..serve.serve_step import Engine
from ..sharding.env import use_mesh
from .train import parse_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=8)
    ap.add_argument("--perf", action="store_true")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        jax.distributed.initialize()
    if args.perf:
        set_perf(TUNED)
    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = parse_mesh(args.mesh)
    with use_mesh(mesh) as env:
        from .dryrun import _resolve_tree
        params, specs = lm.init_params(cfg, jax.random.key(0))
        params = jax.tree.map(jax.device_put, params,
                              _resolve_tree(env, specs))
        engine = Engine(cfg, params,
                        s_max=args.prompt_len + args.n_new + 8)
        kw = {}
        if cfg.family == "encdec":
            import jax.numpy as jnp
            kw["enc_frames"] = jnp.zeros((args.batch, cfg.enc_seq,
                                          cfg.d_model), jnp.bfloat16)
        prompts = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab)
        out = engine.generate(prompts, n_new=args.n_new, **kw)
        for i in range(args.batch):
            print(f"req {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()

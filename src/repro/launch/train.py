"""Production training launcher: mesh + sharded state + fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --mesh 1x1 --smoke --steps 20          # single device, CPU
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --mesh 2x4 --smoke --steps 10          # 8 host devices, dp=2 tp=4

On a real pod the same entrypoint takes --mesh 16x16 / 2x16x16 (the
dry-run-validated configurations) — jax.distributed.initialize() is called
when JAX_COORDINATOR_ADDRESS is set, so multi-host launch is `srun/gxm`
of this module on every host.
"""
from __future__ import annotations

import argparse
import logging
import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..data.pipeline import DataConfig, SyntheticPipeline
from ..models import lm
from ..models.perf import TUNED, set_perf
from ..sharding.env import use_mesh
from ..train.optimizer import AdamWConfig, OptState, init_opt_state
from ..train.train_step import train_step
from ..ckpt.checkpoint import CheckpointManager


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-launch-train")
    ap.add_argument("--perf", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        jax.distributed.initialize()
    if args.perf:
        set_perf(TUNED)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = parse_mesh(args.mesh)
    with use_mesh(mesh) as env:
        from .dryrun import _resolve_tree
        params, specs = lm.init_params(cfg, jax.random.key(0))
        p_shard = _resolve_tree(env, specs)
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt = init_opt_state(params)
        o_shard = OptState(NamedSharding(mesh, P()), p_shard, p_shard)
        ocfg = AdamWConfig(warmup_steps=5, total_steps=args.steps)
        step_fn = jax.jit(lambda p, o, b: train_step(cfg, ocfg, p, o, b),
                          in_shardings=(p_shard, o_shard, None),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))
        pipe = SyntheticPipeline(cfg, DataConfig(args.batch, args.seq))
        ckpt = CheckpointManager(args.ckpt_dir)
        start = ckpt.latest_step() or 0
        if start:
            state = ckpt.restore({"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"resumed from step {start}")
        for step in range(start, args.steps):
            params, opt, m = step_fn(params, opt, pipe.batch_at(step))
            if (step + 1) % 5 == 0:
                print(f"step {step+1}: loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f}")
        ckpt.save(args.steps, {"params": params, "opt": opt}, blocking=True)
        print(f"done; checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()

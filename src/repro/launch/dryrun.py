import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, record memory/cost/roofline — NO device allocation
(everything flows through ShapeDtypeStruct).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, all_archs, get_config
from ..roofline import analysis as RA
from ..sharding.env import get_env, use_mesh
from ..serve import serve_step
from ..train.optimizer import AdamWConfig, OptState
from ..train.train_step import train_step
from . import mesh as M
from .specs import input_specs


def _is_spec_leaf(x) -> bool:
    """A spec leaf is a tuple of (None | logical-name | tuple of names);
    a tuple of specs (e.g. a KV-cache pair) is NOT a leaf."""
    if not isinstance(x, tuple):
        return False
    return all(e is None or isinstance(e, str)
               or (isinstance(e, tuple) and e
                   and all(isinstance(a, str) for a in e))
               for e in x)


def _resolve_tree(env, spec_tree):
    """Logical spec tree -> NamedSharding tree."""
    from ..sharding.env import _resolve

    def conv(s):
        phys = [_resolve(env, part) for part in s]
        return NamedSharding(env.mesh, P(*phys))

    return jax.tree.map(conv, spec_tree, is_leaf=_is_spec_leaf)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             perf: bool = False) -> dict:
    from ..models.perf import BASELINE, TUNED, set_perf
    set_perf(TUNED if perf else BASELINE)
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec = {"arch": arch, "shape": shape_name, "perf": perf,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips}
    t0 = time.perf_counter()
    with use_mesh(mesh) as env:
        spec = input_specs(arch, shape_name)
        cfg, shape = spec["cfg"], spec["shape"]
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        if spec["skip"]:
            rec["status"] = "skipped"
            rec["reason"] = spec["skip"]
            return rec

        p_structs, p_specs = spec["params"]
        p_shard = _resolve_tree(env, p_specs)

        if shape.kind == "train":
            b_structs, b_specs = spec["batch"]
            o_structs, o_specs = spec["opt"]
            b_shard = _resolve_tree(env, b_specs)
            o_shard = OptState(NamedSharding(mesh, P()),
                               _resolve_tree(env, o_specs.m),
                               _resolve_tree(env, o_specs.v))
            ocfg = AdamWConfig()
            fn = lambda p, o, b: train_step(cfg, ocfg, p, o, b)
            jfn = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(p_structs, o_structs, b_structs)
        elif shape.kind == "prefill":
            b_structs, b_specs = spec["batch"]
            b_shard = _resolve_tree(env, b_specs)
            fn = partial(serve_step.prefill, cfg)
            jfn = jax.jit(lambda p, b: fn(p, **b),
                          in_shardings=(p_shard, b_shard))
            lowered = jfn.lower(p_structs, b_structs)
        else:  # decode
            t_struct, t_spec = spec["token"]
            c_structs, c_specs = spec["caches"]
            t_shard = _resolve_tree(env, {"t": t_spec})["t"]
            c_shard = _resolve_tree(env, c_specs)
            args = [p_structs, t_struct, c_structs,
                    jax.ShapeDtypeStruct((), jnp.int32)]
            shards = [p_shard, t_shard, c_shard, NamedSharding(mesh, P())]
            if cfg.family == "encdec":
                x_structs, x_specs = spec["cross"]
                fn = lambda p, t, c, n, x: serve_step.decode(
                    cfg, p, t, c, n, cross_kvs=x)
                args.append(x_structs)
                shards.append(_resolve_tree(env, x_specs))
            else:
                fn = lambda p, t, c, n: serve_step.decode(cfg, p, t, c, n)
            jfn = jax.jit(fn, in_shardings=tuple(shards),
                          out_shardings=(None, c_shard),
                          donate_argnums=(2,))   # in-place cache update
            lowered = jfn.lower(*args)

        rec["lower_s"] = round(time.perf_counter() - t0, 1)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 1)

        mem = compiled.memory_analysis()
        try:
            rec["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception:
            rec["memory_analysis"] = {"repr": repr(mem)}
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jaxlib returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        roof = RA.analyze(hlo, cost, cfg, shape, n_chips)
        rec["roofline"] = roof.to_json()
        rec["hlo_bytes"] = len(hlo)
        rec["status"] = "ok"
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"compile {rec['compile_s']}s, dominant={roof.dominant}, "
              f"compute={roof.compute_s:.4f}s mem={roof.memory_s:.4f}s "
              f"coll={roof.collective_s:.4f}s useful={roof.useful_ratio:.2f}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--perf", action="store_true",
                    help="use the TUNED perf profile (§Perf hillclimb)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            with open(out_path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {tag}: cached, skipping", flush=True)
                    continue
        try:
            rec = run_cell(arch, shape, mp, perf=args.perf)
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": str(e),
                   "traceback": traceback.format_exc()}
            print(f"[dryrun] {tag}: ERROR {e}", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()

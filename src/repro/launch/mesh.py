"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run entrypoint (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches import jax normally and see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline targets; DESIGN.md §7)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
